"""Live-workload failover: the hardened request plane, the control-plane
bridge, and the end-to-end drill.

Covers the request-plane state machine (fail-fast admission, shedding,
deadlines, bounded retries, preempt/hold/restore), the scheduler-level
failure accounting, the starvation-aging fix, §4.2 availability folding
preempted-and-never-restored work, timeline-trace ⇄ replica-actuation
parity (both drive modes of ``FailoverBridge``), a deterministic
end-to-end drill with differentiated user-visible SLAs, and a chaos
campaign over the request-plane fault families with bit-exact replay.
"""

import jax
import numpy as np
import pytest

from repro import obs
from repro.core.tiers import FailureClass, RTO_SECONDS, Tier
from repro.core.timeline_sim import default_ts, simulate_timeline
from repro.models import LMConfig, init_params
from repro.serving import (DrillSpec, FailoverBridge, Request,
                           ServingEngine, TieredScheduler, TierPolicy,
                           drill_oracle, request_campaign, run_drill,
                           tier_live_fractions)
from repro.serving.workload import _engine_pool, _sim_for

CFG = LMConfig(name="sf", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
               d_head=16, d_ff=128, vocab_size=128, tie_embeddings=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))
RTO = RTO_SECONDS[FailureClass.RESTORE_LATER]


def _engine(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 32)
    return ServingEngine(CFG, PARAMS, **kw)


def _req(rid, tier, plen=4, new=2):
    return Request(rid, tier=tier, prompt=list(range(plen)),
                   max_new_tokens=new)


def _serve_all(sched, t0=0.0, dt=1.0, max_rounds=200):
    t = t0
    for _ in range(max_rounds):
        t += dt
        busy = sched.tick(now=t)
        if not busy and not sched._q and not sched._retry:
            return t
    raise AssertionError("scheduler did not drain")


# ---------------------------------------------------------------------------
# request-plane hardening: the per-request state machine
# ---------------------------------------------------------------------------

def test_fail_fast_rejects_blocked_tier_at_scheduler():
    e = _engine()
    sched = TieredScheduler({"e": e})
    sched.block_tier(Tier.T5, now=10.0)
    r = _req(0, Tier.T5)
    sched.submit(r, now=10.0)
    assert r.state == "rejected" and r.fail_reason == "rejected"
    assert sched.counters["rejected"][Tier.T5] == 1
    # charged at the scheduler, never to an engine
    assert e.counters["rejected"][Tier.T5] == 0
    assert sched.queue_depth(Tier.T5) == 0


def test_queue_bound_sheds_overload():
    sched = TieredScheduler({"e": _engine()},
                            policies={Tier.T5: TierPolicy(queue_bound=2)})
    rs = [_req(i, Tier.T5) for i in range(3)]
    for r in rs:
        sched.submit(r, now=0.0)
    assert [r.state for r in rs] == ["queued", "queued", "failed"]
    assert rs[2].fail_reason == "shed"
    assert sched.counters["shed"][Tier.T5] == 1


def test_deadline_expiry_is_lazy_and_counted():
    sched = TieredScheduler({"e": _engine()},
                            policies={Tier.T1: TierPolicy(deadline_s=5.0)})
    r = _req(0, Tier.T1)
    sched.submit(r, now=0.0)
    sched.tick(now=100.0)           # way past the budget: expire on pop
    assert r.state == "failed" and r.fail_reason == "deadline"
    assert sched.counters["deadline"][Tier.T1] == 1
    assert sched.counters["served"][Tier.T1] == 0


def test_retry_budget_exhaustion_marks_failed():
    e = _engine()
    sched = TieredScheduler({"e": e},
                            policies={Tier.T3: TierPolicy(max_retries=0)})
    r = _req(0, Tier.T3)
    sched.submit(r, now=0.0)
    sched.tick(now=1.0)
    assert r.state == "running"
    # capacity-dip preemption of an unblocked tier: immediate requeue
    # path, but the budget is 0 retries -> fails terminally
    sched.absorb_preempted(e, e.preempt())
    assert r.state == "failed" and r.fail_reason == "retry_exhausted"
    assert sched.counters["retry_exhausted"][Tier.T3] == 1
    assert e.counters["restored"][Tier.T3] == 1   # no longer held anywhere


def test_preempt_hold_restore_roundtrip():
    e = _engine()
    sched = TieredScheduler({"e": e}, seed=3)
    r = _req(0, Tier.T3)
    sched.submit(r, now=0.0)
    sched.tick(now=1.0)
    assert r.state == "running"

    sched.block_tier(Tier.T3, now=2.0)
    # running wave preempted and *held* (not failed) during the blackout
    assert r.state == "preempted"
    assert sched.preempted_pending(Tier.T3) == 1
    assert sched.counters["preempted"][Tier.T3] == 1
    # held work counts against the preemptible tier's availability (§4.2)
    assert sched.availability(Tier.T3) == 0.0
    assert e.availability(Tier.T3) == 0.0

    sched.restore_tier(Tier.T3, now=100.0)
    assert sched.preempted_pending(Tier.T3) == 0
    assert sched.counters["requeued"][Tier.T3] == 1
    assert r.attempts == 1
    _serve_all(sched, t0=100.0, dt=10.0)          # ride out the backoff
    assert r.state == "done"
    # re-prefilled: outputs restarted, nothing carried from the first try
    assert len(r.output) == r.max_new_tokens
    assert sched.availability(Tier.T3) == 1.0
    assert e.availability(Tier.T3) == 1.0


def test_retry_backoff_is_exponential_with_jitter():
    pol = TierPolicy(backoff_base_s=10.0, backoff_mult=2.0, jitter_frac=0.1)
    assert pol.backoff(1, 0.0) == 10.0
    assert pol.backoff(2, 0.0) == 20.0
    assert pol.backoff(3, 1.0) == pytest.approx(44.0)   # 40 * 1.1


# ---------------------------------------------------------------------------
# satellite: scheduler-level failover accounting (not an arbitrary engine)
# ---------------------------------------------------------------------------

def test_enter_failover_charges_rejections_to_scheduler():
    engines = {"e0": _engine(), "e1": _engine()}
    sched = TieredScheduler(engines)
    for i in range(4):
        sched.submit(_req(i, Tier.T5), now=0.0)
    sched.submit(_req(9, Tier.T1), now=0.0)
    sched.enter_failover(now=1.0)
    # the drained queue is rejected once, at the scheduler
    assert sched.counters["rejected"][Tier.T5] == 4
    for e in engines.values():
        assert e.counters["rejected"][Tier.T5] == 0
    # critical work is untouched and still drains
    assert sched.queue_depth(Tier.T1) == 1
    _serve_all(sched, t0=1.0)
    assert sched.counters["served"][Tier.T1] == 1


# ---------------------------------------------------------------------------
# satellite: starvation aging actually reorders the heap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("aging_rounds,starved", [(2, False), (0, True)])
def test_starvation_aging_promotes_ancient_low_tier(aging_rounds, starved):
    sched = TieredScheduler({"e": _engine(max_batch=1)},
                            aging_rounds=aging_rounds)
    ancient = _req(0, Tier.T5)
    sched.submit(ancient, now=0.0)
    # a continuous stream of fresh critical arrivals outranks T5 on raw
    # tier priority forever; aging must bound the starvation
    for i in range(40):
        sched.submit(_req(100 + i, Tier.T0), now=float(i))
        sched.tick(now=float(i))
    if starved:
        assert ancient.state == "queued"      # disabled aging: starved
    else:
        assert ancient.state == "done"        # promoted past fresh T0s


# ---------------------------------------------------------------------------
# satellite: engine availability folds preempted-and-never-restored (§4.2)
# ---------------------------------------------------------------------------

def test_engine_availability_counts_unrestored_preemptions():
    e = _engine()
    done = [_req(i, Tier.T5) for i in range(3)]
    e.admit(done)
    while e.decode_round():
        pass
    assert e.availability(Tier.T5) == 1.0
    lost = _req(9, Tier.T5)
    e.admit([lost])
    e.preempt()
    # never restored: counts against the preemptible tier's SLA
    assert e.availability(Tier.T5) == pytest.approx(0.75)
    e.restored_credit(lost)        # requeued post-restore: back in flight
    assert e.availability(Tier.T5) == 1.0


# ---------------------------------------------------------------------------
# timeline-trace ⇄ replica-actuation parity
# ---------------------------------------------------------------------------

def test_trace_actuation_parity_with_timeline_kernel():
    spec = DrillSpec()
    rep = run_drill(spec)
    cfg, sim = _sim_for(spec.scale, spec.fleet_seed, spec.horizon_s,
                        spec.n_steps, spec.traffic_mult)
    _, groups = _engine_pool(spec.crit_tier, spec.pre_tier,
                             spec.crit_replicas, spec.crit_standby,
                             spec.pre_replicas, spec.max_batch,
                             spec.prompt_len + spec.max_new_tokens + 8)
    # replay the actuation formula straight off the capacity traces
    expected, cur = [], {g.tier: g.base for g in groups}
    for i in range(spec.n_steps):
        frac = tier_live_fractions(sim, cfg, i)
        for g in groups:
            tgt = FailoverBridge.target_for(g, float(frac[g.tier]))
            if tgt != cur[g.tier]:
                expected.append((float(sim["t"][i]), g.tier, tgt))
                cur[g.tier] = tgt
    assert rep.actuation_log == expected
    # the preemptible tier blacks out and comes back; Always-On upscales
    pre_targets = [tgt for _, t, tgt in rep.actuation_log
                   if t == spec.pre_tier]
    assert pre_targets[0] == 0 and pre_targets[-1] > 0
    assert any(tgt > spec.crit_replicas for _, t, tgt in rep.actuation_log
               if t == spec.crit_tier)


def test_orchestrator_bind_matches_trace_drive():
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.core.service import synthesize_fleet

    spec = DrillSpec()
    engines, groups = _engine_pool(spec.crit_tier, spec.pre_tier,
                                   spec.crit_replicas, spec.crit_standby,
                                   spec.pre_replicas, spec.max_batch,
                                   spec.prompt_len + spec.max_new_tokens + 8)

    def fresh_bridge():
        for e in engines.values():
            e.reset()
        return FailoverBridge(TieredScheduler(engines), groups)

    fleet = synthesize_fleet(scale=spec.scale, seed=spec.fleet_seed)
    orch = Orchestrator(fleet, RegionCapacity.for_fleet("r", fleet))
    cfg = orch.timeline_config()           # extract BEFORE the failover

    # drive mode 1: the timeline kernel's trace
    trace = fresh_bridge()
    sim = simulate_timeline(cfg, ts=default_ts(spec.horizon_s, spec.n_steps))
    trace.drive_trace(sim, cfg)

    # drive mode 2: live Orchestrator events
    live = fresh_bridge()
    live.bind(orch)
    orch.failover(tv_failover=1.0)

    def targets(bridge, tier):
        out = []
        for _, t, tgt in bridge.log:
            if t == tier and (not out or out[-1] != tgt):
                out.append(tgt)
        return out

    # same actuation sequence per tier from either drive mode
    for g in groups:
        assert targets(live, g.tier) == targets(trace, g.tier), g.tier
        assert live.active_count(g.tier) == trace.active_count(g.tier)


# ---------------------------------------------------------------------------
# the end-to-end drill: deterministic, differentiated user-visible SLAs
# ---------------------------------------------------------------------------

def test_live_drill_end_to_end_differentiated_slas():
    spec = DrillSpec()
    reg = obs.enable()
    reg.reset()
    try:
        rep = run_drill(spec)
    finally:
        obs.disable()
    crit, pre = rep.crit, rep.pre

    # critical tier rides through the full-peak failover untouched
    assert rep.sla_ok
    assert crit.availability >= spec.avail_slo
    assert not crit.slo_alert
    assert crit.p99_s <= spec.crit_p99_slo_s
    assert crit.rejected == crit.shed == crit.retry_exhausted == 0

    # preemptible tier degrades visibly but restores within its RTO
    assert pre.rejected > 0                 # fail-fast during the blackout
    assert pre.preempted > 0 and pre.requeued > 0
    assert pre.availability < crit.availability
    assert np.isfinite(pre.time_to_restore_s)
    assert 0.0 < pre.time_to_restore_s <= RTO
    assert pre.slo_alert                    # burn-rate monitor fires
    assert pre.served > 0                   # requeued work completes

    # measured through the obs plane, not just the scheduler
    assert obs.value("ufa_serving_requests_total",
                     tier=crit.tier, outcome="served") == crit.served
    assert obs.value("ufa_serving_requests_total",
                     tier=pre.tier, outcome="rejected") == pre.rejected
    assert obs.value("ufa_serving_retries_total",
                     tier=pre.tier) == pre.requeued

    # availability trace feeding the SLO monitor is step-aligned
    assert rep.avail_trace[spec.pre_tier].shape == (spec.n_steps,)
    assert rep.avail_trace[spec.pre_tier].min() < spec.avail_slo


def test_live_drill_is_bit_deterministic():
    spec = _small_spec()
    a, b = run_drill(spec), run_drill(spec)
    assert a.sla_ok == b.sla_ok and a.users_served == b.users_served
    assert a.actuation_log == b.actuation_log
    for t in a.tiers:
        assert a.tiers[t].as_dict() == b.tiers[t].as_dict()
        np.testing.assert_array_equal(a.avail_trace[t], b.avail_trace[t])


# ---------------------------------------------------------------------------
# chaos integration: the drill as a campaign target + bit-exact replay
# ---------------------------------------------------------------------------

def _small_spec():
    """Cheaper drill for campaign tests: coarser steps, thinner load,
    short decodes so service time stays inside the p99 budget."""
    return DrillSpec(horizon_s=7200.0, n_steps=48, ticks_per_step=4,
                     crit_rps=0.03, pre_rps=0.02, max_new_tokens=2,
                     seed=11)


def test_request_fault_families_registered_globally():
    from repro.chaos.faults import FAMILIES, FAULT_LIBRARY, REQUEST_FAMILIES
    assert REQUEST_FAMILIES == ("arrival_spike", "retry_storm")
    for name in REQUEST_FAMILIES:
        assert name in FAULT_LIBRARY
        assert name not in FAMILIES     # never leaks into engine grids
    assert FAULT_LIBRARY["arrival_spike"].knob == "arrival_mult"
    assert FAULT_LIBRARY["retry_storm"].knob == "retry_storm"


def test_request_campaign_localizes_arrival_frontier_and_replays():
    from repro.chaos import verify_report
    from repro.chaos.campaign import Ray

    spec = _small_spec()
    camp = request_campaign(
        spec, rays=(Ray("arrival_spike", {"arrival_spike": 1.0}),),
        tol=1.0 / 4.0, max_rounds=3)
    rep = camp.run()
    assert rep.op_ok                       # operating point passes its SLA
    ray = rep.ray("arrival_spike")
    assert ray.status == "localized"
    assert 0.0 < ray.frontier_severity < 1.0
    knobs = ray.frontier_knobs()
    assert knobs["arrival_mult"] > 1.0     # frontier in knob coordinates
    assert ray.counterexample["arrival_mult"] > knobs["arrival_mult"]

    # bit-exact replay through a fresh oracle (fresh drills per row)
    out = verify_report(rep, oracle=drill_oracle(spec))
    assert out["n_probes"] == rep.n_evals and not out["mismatches"]


def test_drill_oracle_grid_contract():
    oracle = drill_oracle(_small_spec())
    ok, res = oracle({"arrival_mult": np.array([1.0]),
                      "retry_storm": np.array([0.0])})
    assert ok.shape == (1,) and bool(ok[0])
    for k in ("sla_ok", "crit_availability", "crit_p99_s", "pre_restore_s"):
        assert res[k].shape == (1,)
    assert res["crit_availability"][0] >= 0.9997
