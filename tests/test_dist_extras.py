"""Coverage for dist/ctx hints, windowed sharded split-KV decode, traffic
routing, and the train/serve launcher CLIs."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_hint_is_noop_without_context():
    from repro.dist.ctx import hint
    x = jnp.ones((4, 8))
    assert hint(x, "batch", "ff") is x


def test_hint_skips_nondivisible_dims():
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.dist.ctx import sharding_rules, hint, axis_size
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        with sharding_rules(mesh):
            assert axis_size("batch") == 2
            assert axis_size("ff") == 2
            x = jnp.ones((3, 5))   # neither dim divides -> fully unpinned
            y = hint(x, "batch", "ff")
            x2 = jnp.ones((4, 8))
            y2 = hint(x2, "batch", "ff")
        print("OK")
    """)
    _run(4, code)


def test_windowed_sharded_splitkv_decode():
    """gemma-style mixed local/global layers must decode correctly with the
    sequence-sharded quantized and unquantized caches."""
    code = textwrap.dedent("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.dist.ctx import sharding_rules
        from repro.dist import sharding as shd
        from repro.models import (LMConfig, init_params, init_decode_state,
                                  decode_step)
        cfg = LMConfig(name="w", n_layers=4, d_model=64, n_heads=4,
                       n_kv_heads=2, d_head=16, d_ff=128, vocab_size=128,
                       window_pattern=(4, 4, 4, 0), rope_theta_local=1e3)
        p = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 128)
        st = init_decode_state(cfg, 2, 16, jnp.float32)
        ref = []
        for t in range(8):
            lg, st = decode_step(p, cfg, st, toks[:, t])
            ref.append(lg)
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        for quant, tol in ((False, 5e-3), (True, 0.05)):
            c = dataclasses.replace(cfg, kv_quant=quant)
            st2 = init_decode_state(c, 2, 16, jnp.float32)
            st2 = jax.device_put(st2, shd.decode_state_shardings(c, mesh, 2))
            def step(st, tok):
                with sharding_rules(mesh):
                    return decode_step(p, c, st, tok)
            jstep = jax.jit(step, donate_argnums=(0,))
            with mesh:
                got = []
                for t in range(8):
                    lg, st2 = jstep(st2, toks[:, t])
                    got.append(lg)
            err = max(float(jnp.abs(a - b).max()) for a, b in zip(ref, got))
            rel = err / max(float(jnp.abs(a).max()) for a in ref)
            assert rel < tol, (quant, rel)
        print("OK")
    """)
    _run(4, code)


def test_traffic_region_routing():
    from repro.core.traffic import make_cities, region_traffic
    cities = make_cities(20)
    assign = {c.name: c.home_region for c in cities}
    t = region_traffic(cities, assign, 3600.0)
    assert set(t) == {"regionA", "regionB"}
    total = sum(t.values())
    # failover: everything to regionB
    assign_fo = {c.name: "regionB" for c in cities}
    t2 = region_traffic(cities, assign_fo, 3600.0)
    assert t2["regionB"] == pytest.approx(total, rel=1e-9)
    assert "regionA" not in t2


def test_train_launcher_cli():
    out = _run(1, textwrap.dedent("""
        import sys
        sys.argv = ["train", "--arch", "llama3.2-3b", "--steps", "3",
                    "--ckpt-dir", "/tmp/repro_cli_ckpt"]
        from repro.launch.train import main
        main()
    """))
    assert "done: 3 steps" in out


def test_serve_launcher_cli():
    out = _run(1, textwrap.dedent("""
        import sys
        sys.argv = ["serve", "--arch", "gemma3-4b", "--requests", "8",
                    "--failover-at", "4"]
        from repro.launch.serve import main
        main()
    """))
    assert "tokens decoded" in out
