"""Observability plane: registry semantics, SLO monitor parity, trace
schema, Prometheus round-trip, and the event-loop tie-order regression.

The default registry is process-global and *disabled* — every test that
enables it must restore the disabled/empty state so instrumentation
stays free for the rest of the suite.
"""

import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core.events import EventLoop
from repro.obs import export, slo
from repro.obs.registry import Registry


@pytest.fixture
def default_obs():
    """Enable the process-global registry, restore disabled+empty after."""
    reg = obs.enable()
    reg.reset()
    tracer0 = obs.get_tracer()
    yield reg
    obs.set_tracer(tracer0)
    obs.disable()
    reg.reset()


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    reg = Registry()
    c = reg.counter("c_total", "help", labels=("k",))
    c.labels(k="a").inc()
    c.labels(k="a").inc(2.5)
    c.labels(k="b").inc()
    assert reg.value("c_total", k="a") == 3.5
    assert reg.value("c_total", k="b") == 1.0
    assert reg.value("c_total", k="missing") == 0.0
    with pytest.raises(ValueError):
        c.labels(k="a").inc(-1)

    g = reg.gauge("g")
    g.set(7.0)
    g.dec(2.0)
    assert reg.value("g") == 5.0

    h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 0.5, 10.0):
        h.observe(v)
    row = [r for r in reg.collect() if r["name"] == "h_seconds"][0]
    assert row["count"] == 4
    assert row["sum"] == pytest.approx(11.05)
    # bucket counts are CUMULATIVE and the +Inf bucket equals count
    assert row["buckets"] == [[0.1, 1], [1.0, 3], [float("inf"), 4]]


def test_registry_get_or_create_and_kind_conflicts():
    reg = Registry()
    a = reg.counter("x_total", "first", labels=("k",))
    b = reg.counter("x_total", "ignored", labels=("k",))
    assert a is b
    with pytest.raises(ValueError):
        reg.gauge("x_total")                     # kind redefinition
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("other",))  # label redefinition


def test_disabled_registry_is_noop():
    reg = Registry(enabled=False)
    c = reg.counter("c_total")
    c.inc(100)
    reg.gauge("g").set(4)
    reg.histogram("h").observe(1.0)
    assert reg.value("c_total") == 0.0
    assert reg.value("g") == 0.0
    for row in reg.collect():
        assert row.get("value", 0.0) == 0.0
        assert row.get("count", 0) == 0


def test_default_registry_helpers_free_when_off(default_obs):
    obs.disable()
    obs.inc("ufa_sweep_runs_total")
    obs.set_gauge("ufa_sweep_scenarios_per_s", 123.0)
    assert obs.value("ufa_sweep_runs_total") == 0.0
    obs.enable()
    obs.inc("ufa_sweep_runs_total")
    obs.inc("ufa_ingest_records_total", 10, backend="numpy")
    assert obs.value("ufa_sweep_runs_total") == 1.0
    assert obs.value("ufa_ingest_records_total", backend="numpy") == 10.0
    kind, help_, _ = obs.describe("ufa_ingest_records_total")
    assert kind == "counter" and help_


def test_helpers_allow_label_literally_named_name(default_obs):
    # ufa_bench_us_per_call's label IS "name" — the helpers take their
    # metric-name/value arguments positional-only so this cannot collide
    obs.set_gauge("ufa_bench_us_per_call", 12.5, name="row_a")
    assert obs.value("ufa_bench_us_per_call", name="row_a") == 12.5


def test_registry_thread_reentrancy():
    reg = Registry()
    c = reg.counter("t_total", labels=("k",))

    def worker(k):
        for _ in range(2000):
            c.labels(k=k).inc()

    threads = [threading.Thread(target=worker, args=(f"w{i % 3}",))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = sum(reg.value("t_total", k=f"w{i}") for i in range(3))
    assert total == 6 * 2000


# ---------------------------------------------------------------------------
# event loop: deferred re-push keeps the original tie order
# ---------------------------------------------------------------------------

def test_event_loop_deferred_event_keeps_tie_order():
    loop = EventLoop()
    order = []
    loop.schedule(10.0, lambda: order.append("A"))
    loop.schedule(10.0, lambda: order.append("B"))
    # partial run defers A (popped, beyond the horizon, re-pushed)
    assert loop.run(until=5.0) == 0
    # a later-scheduled same-time event must still fire AFTER A and B
    loop.schedule(10.0, lambda: order.append("C"))
    loop.run()
    assert order == ["A", "B", "C"]
    assert loop.now == 10.0


def test_event_loop_counts_events_when_obs_on(default_obs):
    loop = EventLoop()
    loop.schedule(1.0, lambda: None, label="wave")
    loop.schedule(2.0, lambda: None, label="wave")
    loop.run()
    assert obs.value("ufa_orch_events_total", label="wave") == 2.0


# ---------------------------------------------------------------------------
# SLO burn-rate monitor: jitted path == numpy reference, exact alert times
# ---------------------------------------------------------------------------

def _trace(dips, n=240, dt=30.0):
    """Availability trace: 1.0 except [i0, i1) steps pinned to `avail`."""
    ts = np.arange(n) * dt
    avail = np.ones(n)
    for i0, i1, a in dips:
        avail[i0:i1] = a
    return avail, ts


def test_slo_alerts_np_fires_on_deep_dip_only():
    # deep long dip: burn = (1-0.99)/(0.0003) = 33x >> both thresholds
    avail, ts = _trace([(10, 120, 0.99)])
    v = slo.alerts_np(avail, ts)
    assert bool(v["alert"])
    assert np.isfinite(v["t_first_alert"])
    assert v["burn_peak"] > 14.4
    # healthy trace at exactly the target burns at 1x: no alert
    avail2 = np.full(240, slo.DEFAULT_TARGET)
    v2 = slo.alerts_np(avail2, ts)
    assert not bool(v2["alert"])
    assert v2["t_first_alert"] == float("inf")
    assert int(v2["rule_first_alert"]) == -1


def test_slo_sweep_alerts_matches_numpy_reference_exactly():
    traces = [
        _trace([])[0],                          # clean
        _trace([(10, 120, 0.99)])[0],           # deep sustained dip
        _trace([(5, 12, 0.95)])[0],             # short sharp spike
        _trace([(0, 240, 0.9995)])[0],          # mild burn, never alerts
        _trace([(200, 240, 0.98)])[0],          # late dip
    ]
    ts = _trace([])[1]
    out = slo.sweep_alerts(np.stack(traces), ts)
    assert out["alert"].shape == (5,)
    for i, tr in enumerate(traces):
        ref = slo.alerts_np(tr, ts)
        assert bool(out["alert"][i]) == bool(ref["alert"]), i
        # exact alert-time agreement (well-separated thresholds)
        assert float(out["t_first_alert"][i]) == float(ref["t_first_alert"])
        assert int(out["rule_first_alert"][i]) == int(ref["rule_first_alert"])
    assert bool(out["alert"][0]) is False and bool(out["alert"][1]) is True


def test_slo_sweep_alerts_records_metrics(default_obs):
    avail, ts = _trace([(10, 120, 0.99)])
    out = slo.sweep_alerts(np.stack([avail, np.ones_like(avail)]), ts)
    assert int(out["alert"].sum()) == 1
    assert obs.value("ufa_slo_scenarios_alerting") == 1.0
    ri = int(out["rule_first_alert"][0])
    rule = slo.DEFAULT_RULES[ri]
    assert obs.value("ufa_slo_alerts_total", rule=rule.name) == 1.0


def test_rolling_mean_partial_prefixes():
    x = np.array([4.0, 2.0, 6.0, 8.0])
    got = slo._rolling_mean_np(x, 2)
    assert np.allclose(got, [4.0, 3.0, 4.0, 7.0])


# ---------------------------------------------------------------------------
# Chrome trace schema
# ---------------------------------------------------------------------------

def test_tracer_chrome_schema_valid():
    tr = obs.Tracer()
    tr.sim_span("mbb-wave", 10.0, 40.0, args={"n": 3})
    tr.sim_instant("slo-alert", 25.0)
    with tr.span("host-phase"):
        pass
    doc = tr.to_chrome()
    assert export is not None  # silence linters about unused import chains
    assert obs.validate_chrome_trace(doc) == []
    evs = doc["traceEvents"]
    span = [e for e in evs if e["ph"] == "X" and e["name"] == "mbb-wave"][0]
    # sim time maps 1 s -> 1e6 trace us, spanning scheduled-at -> fired-at
    assert span["ts"] == 10.0 * 1e6 and span["dur"] == 30.0 * 1e6
    names = {e["name"] for e in evs if e["ph"] == "M"}
    assert "process_name" in names and "thread_name" in names


def test_validate_chrome_trace_flags_bad_events():
    bad = {"traceEvents": [
        {"ph": "Z", "name": "x", "pid": 1, "tid": 0, "ts": 0},
        {"ph": "X", "name": "y", "pid": 1, "tid": 0, "ts": 5},   # no dur
    ]}
    problems = obs.validate_chrome_trace(bad)
    assert len(problems) >= 2


def test_event_loop_tracer_emits_spans():
    tr = obs.Tracer()
    loop = EventLoop()
    loop.tracer = tr
    loop.schedule(3.0, lambda: None, label="bbm-evict")
    loop.log("checkpoint")
    loop.run()
    doc = tr.to_chrome()
    assert obs.validate_chrome_trace(doc) == []
    spans = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "bbm-evict"]
    assert len(spans) == 1
    assert spans[0]["ts"] == 0.0 and spans[0]["dur"] == 3.0 * 1e6


# ---------------------------------------------------------------------------
# Prometheus exposition round-trip
# ---------------------------------------------------------------------------

def test_prometheus_round_trip(tmp_path):
    reg = Registry()
    c = reg.counter("rt_total", 'help with "quotes"\nand newline',
                    labels=("backend",))
    c.labels(backend="numpy").inc(5)
    c.labels(backend='we"ird\\nm\ne').inc(2)
    reg.gauge("rt_gauge").set(2.5)
    h = reg.histogram("rt_seconds", buckets=(0.5, 2.0))
    h.observe(0.1)
    h.observe(1.0)

    text = export.to_prometheus(reg)
    assert export.validate_prometheus(text) == []
    fams = export.parse_prometheus(text)
    assert fams["rt_total"]["type"] == "counter"
    vals = {tuple(sorted(lab.items())): v
            for _, lab, v in fams["rt_total"]["samples"]}
    assert vals[(("backend", "numpy"),)] == 5.0
    assert vals[(("backend", 'we"ird\\nm\ne'),)] == 2.0
    assert fams["rt_gauge"]["samples"][0][2] == 2.5
    hsamp = {(s, tuple(sorted(lab.items()))): v
             for s, lab, v in fams["rt_seconds"]["samples"]}
    assert hsamp[("rt_seconds_count", ())] == 2.0
    assert hsamp[("rt_seconds_sum", ())] == pytest.approx(1.1)
    assert hsamp[("rt_seconds_bucket", (("le", "0.5"),))] == 1.0
    assert hsamp[("rt_seconds_bucket", (("le", "+Inf"),))] == 2.0

    # jsonl snapshot appends strict-JSON lines
    p = tmp_path / "m.jsonl"
    export.write_jsonl(str(p), reg, meta={"run": 1})
    export.write_jsonl(str(p), reg, meta={"run": 2})
    lines = p.read_text().strip().splitlines()
    assert len(lines) == 2
    snap = json.loads(lines[1])
    assert snap["meta"]["run"] == 2
    assert any(m["name"] == "rt_total" for m in snap["metrics"])


def test_validate_prometheus_catches_violations():
    bad = ('# TYPE bad_total counter\n'
           'bad_total -1\n')
    errs = export.validate_prometheus(bad)
    assert any("negative" in e for e in errs)
    bad_hist = ('# TYPE h histogram\n'
                'h_bucket{le="1.0"} 5\n'
                'h_bucket{le="+Inf"} 3\n'
                'h_sum 1.0\n'
                'h_count 3\n')
    errs2 = export.validate_prometheus(bad_hist)
    assert errs2                     # non-cumulative buckets flagged


def test_export_cli_validator(tmp_path, default_obs):
    obs.inc("ufa_sweep_runs_total")
    prom = tmp_path / "m.prom"
    export.write_prometheus(str(prom))
    tr = obs.Tracer()
    tr.sim_instant("x", 1.0)
    trace = tmp_path / "t.json"
    tr.save(str(trace))
    assert export._main(["--validate", str(prom),
                         "--validate-trace", str(trace)]) == 0
    trace.write_text('{"traceEvents": [{"ph": "Q"}]}')
    assert export._main(["--validate-trace", str(trace)]) != 0


# ---------------------------------------------------------------------------
# profiler
# ---------------------------------------------------------------------------

def test_profiler_phase_records_and_traces(default_obs):
    from repro.obs.profiler import Profiler
    tr = obs.Tracer()
    prof = Profiler(tr)
    with prof.phase("unit-test-phase"):
        pass
    assert "unit-test-phase" in prof.phases
    assert prof.phases["unit-test-phase"] >= 0.0
    labels = {r["labels"]["phase"]
              for r in obs.default_registry().collect()
              if r["name"] == "ufa_phase_seconds"}
    assert "unit-test-phase" in labels
    assert any(e["ph"] == "X" and e["name"] == "unit-test-phase"
               for e in tr.to_chrome()["traceEvents"])


# ---------------------------------------------------------------------------
# availability_during_failover: swept rescan stays faithful
# ---------------------------------------------------------------------------

def test_availability_sweep_matches_bruteforce_window_lookup():
    from repro.core.capacity import RegionCapacity
    from repro.core.metrics import availability_during_failover
    from repro.core.omg import Orchestrator
    from repro.core.service import synthesize_fleet

    fleet = synthesize_fleet(scale=0.02, seed=1)
    orch = Orchestrator(fleet, RegionCapacity.for_fleet("r", fleet),
                        scale=0.02)
    orch.failover()
    samples = availability_during_failover(fleet, orch, n_samples=64, seed=3)
    assert len(samples) == 64
    ts = [t for t, _ in samples]
    assert ts == sorted(ts)
    assert all(0.0 <= a <= 1.0 for _, a in samples)

    # the single-pointer sweep must agree with the brute-force "last
    # window at or before t" lookup it replaced
    tl = orch.timeline
    down = tl.series.get("rl_not_bursted", [0] * len(tl.t))
    windows = list(zip(tl.t, down))
    t_end = tl.t[-1]
    j = -1
    for i in range(64):
        t = t_end * i / 63
        while j + 1 < len(windows) and windows[j + 1][0] <= t:
            j += 1
        swept = windows[j][1] if j >= 0 else 0.0
        brute = 0.0
        for wt, wd in windows:
            if wt <= t:
                brute = wd
        assert swept == brute, (i, t)


def test_monitor_orchestrator_end_to_end(default_obs):
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.core.service import synthesize_fleet

    fleet = synthesize_fleet(scale=0.02, seed=1)
    orch = Orchestrator(fleet, RegionCapacity.for_fleet("r", fleet),
                        scale=0.02)
    orch.failover()
    rep = slo.monitor_orchestrator(fleet, orch, n_samples=48)
    assert rep["ts"].shape == rep["availability"].shape == (48,)
    assert rep["target"] == slo.DEFAULT_TARGET
    assert isinstance(rep["alert"], bool)
    if rep["alert"]:
        assert np.isfinite(rep["t_first_alert"])
        assert 0 <= rep["rule_first_alert"] < len(slo.DEFAULT_RULES)
