"""End-to-end behaviour tests for the paper's system.

The full UFA story on real ML workloads: a two-tier serving+training cluster
runs under the orchestrator; a pod fails; preemptible work is evicted and the
critical serving job scales; preempted training restores from checkpoint
within RTO; availability is differentiated by tier exactly as the paper's
Figure 8 / Table 4 describe.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.capacity import RegionCapacity
from repro.core.drills import remediate
from repro.core.omg import Orchestrator
from repro.core.service import synthesize_fleet, unsafe_edges
from repro.core.tiers import FailureClass, Tier
from repro.data import SyntheticLMDataset, make_train_iterator
from repro.models import LMConfig, init_params
from repro.serving import Request, ServingEngine, TieredScheduler
from repro.train import make_train_state, make_train_step
from repro.train.trainer import Trainer

CFG = LMConfig(name="sys", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
               d_head=16, d_ff=128, vocab_size=128, tie_embeddings=True)


def test_end_to_end_ufa_failover_with_real_workloads():
    # --- control plane: fleet + remediation + orchestrator -------------
    fleet = synthesize_fleet(scale=0.02, seed=4)
    remediate(fleet, set(unsafe_edges(fleet)))
    region = RegionCapacity.for_fleet("r", fleet)

    # --- data plane: a critical serving engine + a preemptible trainer --
    params = init_params(CFG, jax.random.PRNGKey(0))
    engine = ServingEngine(CFG, params, max_batch=4, max_seq=48)
    sched = TieredScheduler({"e": engine})
    step_fn, opt = make_train_step(CFG, n_loss_chunks=2)
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4, seed=1)

    events = {"evicted": 0, "restored": 0}

    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(CFG, step_fn, ckdir, checkpoint_every=2)
        tstate = make_train_state(CFG, jax.random.PRNGKey(0), opt)
        # batch training runs opportunistically in overcommit capacity
        tstate, rep0 = trainer.run(tstate, make_train_iterator(ds), 4)

        def on_evict(spec):
            events["evicted"] += 1
            if events["evicted"] == 1:     # preempt the training job (BBM)
                trainer.request_preempt()
                sched.enter_failover()

        def on_restore(spec):
            events["restored"] += 1

        orch = Orchestrator(fleet, region, scale=0.02,
                            on_evict=on_evict, on_restore=on_restore)
        report = orch.failover(tv_failover=1.0)

        # serve during the failover window: critical only
        rng = np.random.default_rng(0)
        for i in range(12):
            sched.submit(Request(i, tier=Tier(i % 6),
                                 prompt=list(rng.integers(0, 128, 8)),
                                 max_new_tokens=2))
        for _ in range(40):
            sched.tick()

        # --- assertions: the paper's claims -----------------------------
        assert report.mode == "peak"
        assert report.always_on_ok                      # Fig 8: no impact
        assert report.rl_rto_met                        # Table 4: <= 1h
        assert events["evicted"] > 0 and events["restored"] > 0
        assert engine.availability(Tier.T1) == 1.0      # critical unharmed
        assert engine.counters["served"][Tier.T5] == 0  # preempted tier dark

        # restore the preempted training job from checkpoint (BBM revive)
        sched.exit_failover()
        t2 = make_train_state(CFG, jax.random.PRNGKey(7), opt)
        t2, start = trainer.maybe_resume(t2)
        assert start >= 4
        trainer._preempt_requested = False
        t2, rep2 = trainer.run(t2, make_train_iterator(ds, start_step=start),
                               3, start_step=start)
        assert rep2.steps_done == 3                     # training continues

        orch.failback()
        for s in orch.se.values():
            assert s.placement == "steady"


def test_unremediated_fleet_fails_certification():
    """Without dependency hardening, the same failover breaks availability —
    the paper's Problem 2 motivating the whole safety pipeline."""
    from repro.core.drills import failover_certification
    fleet = synthesize_fleet(scale=0.02, seed=4)
    assert unsafe_edges(fleet)
    cert = failover_certification(fleet, scale=0.02)
    assert not cert.availability_ok
    assert not cert.certified
