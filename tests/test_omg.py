"""Orchestrator invariants (unit + hypothesis property tests)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capacity import RegionCapacity
from repro.core.omg import Orchestrator
from repro.core.service import synthesize_fleet, unsafe_edges
from repro.core.drills import (dependency_safety_certification,
                               failover_certification, remediate)
from repro.core.tiers import RTO_SECONDS, FailureClass


def _orch(seed=1, scale=0.02):
    fleet = synthesize_fleet(scale=scale, seed=seed)
    region = RegionCapacity.for_fleet("r", fleet)
    return fleet, Orchestrator(fleet, region, scale=scale)


def test_nonpeak_failover_preempts_nothing():
    fleet, orch = _orch()
    rep = orch.failover(tv_failover=0.3)
    assert rep.mode == "non-peak"
    for s in orch.se.values():
        assert s.placement != "down"
        assert s.replicas_live > 0


def test_peak_failover_sequence():
    fleet, orch = _orch()
    rep = orch.failover(tv_failover=1.0)
    assert rep.mode == "peak"
    assert rep.always_on_ok
    assert rep.burst_full_at_s is not None and rep.burst_full_at_s < 20 * 60
    assert rep.rl_rto_met
    # Terminate stays down through the failover
    for s in orch.se.values():
        if s.spec.failure_class == FailureClass.TERMINATE:
            assert s.placement == "down"
        if s.spec.failure_class == FailureClass.ALWAYS_ON:
            assert s.placement == "steady" and s.replicas_live > 0


@given(seed=st.integers(0, 12))
@settings(deadline=None, max_examples=8)
def test_failover_invariants_property(seed):
    fleet, orch = _orch(seed=seed)
    phys = orch.region.steady.physical_cores
    rep = orch.failover(tv_failover=1.0)
    # 1. Always-On never preempted, scaled to 2x
    for s in orch.se.values():
        if s.spec.failure_class == FailureClass.ALWAYS_ON:
            assert s.placement == "steady"
            assert s.replicas_live >= s.spec.replicas
    # 2. steady pool never over-allocated
    assert orch.region.steady.stateless.used <= \
        orch.region.steady.stateless.capacity + 1e-6
    # 3. restore-later all restored within RTO
    assert rep.rl_rto_met
    for s in orch.se.values():
        if s.spec.failure_class == FailureClass.RESTORE_LATER:
            assert s.placement in ("burst", "cloud")
    # 4. failback restores everything and releases resources
    orch.failback()
    for s in orch.se.values():
        assert s.placement == "steady"
        assert not s.locked
        assert s.replicas_live == s.spec.replicas
    assert orch.region.cloud.provisioned == 0
    assert not orch.region.batch.converted


def test_certification_requires_remediation():
    fleet = synthesize_fleet(scale=0.05, seed=3)
    assert unsafe_edges(fleet), "fixture must plant unsafe edges"
    cert0 = failover_certification(fleet, scale=0.05)
    assert not cert0.certified          # fail-close edges present
    remediate(fleet, set(unsafe_edges(fleet)))
    cert1 = failover_certification(fleet, scale=0.05)
    assert cert1.certified
    assert all(cert1.classes_ok.values())


def test_blackhole_drill_finds_unsafe_services():
    fleet = synthesize_fleet(scale=0.05, seed=3)
    res = dependency_safety_certification(fleet, seed=0)
    unsafe_callers = {c for c, _ in unsafe_edges(fleet)
                      if fleet[c].failure_class.survives_failover}
    flagged = {n for n, r in res.items() if not r.certified}
    # every critical caller with an unsafe preemptible dep must fail the drill
    for c in unsafe_callers:
        spec = fleet[c]
        if any(fleet[d].failure_class.preemptible
               for d in spec.unsafe_deps()):
            assert c in flagged
    remediate(fleet, set(unsafe_edges(fleet)))
    res2 = dependency_safety_certification(fleet, seed=0)
    assert all(r.certified for r in res2.values())


def test_up_tier_remediation_changes_class():
    fleet = synthesize_fleet(scale=0.05, seed=3)
    edges = set(unsafe_edges(fleet))
    if not edges:
        pytest.skip("no unsafe edges in fixture")
    remediate(fleet, edges, strategy="up_tier")
    for _, callee in edges:
        assert fleet[callee].failure_class == FailureClass.ACTIVE_MIGRATE
