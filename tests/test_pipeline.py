"""Pipeline parallelism == sequential execution (subprocess, 2/4 stages)."""

import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


CODE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax import lax
    from repro.dist.pipeline import pipeline_apply
    from repro.models import layers as L

    N_STAGES = {n}
    mesh = jax.make_mesh((N_STAGES,), ("pod",))
    Lyr, D, F, B, S = 8, 32, 64, 8, 16
    keys = jax.random.split(jax.random.PRNGKey(0), Lyr)
    params = jax.vmap(lambda k: L.init_mlp(k, D, F))(keys)
    # scale down so activations stay O(1) over 8 residual layers (otherwise
    # fp32 noise on exploding values breaks any absolute tolerance)
    params = jax.tree_util.tree_map(lambda a: a * 0.2, params)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D))

    def one_layer(lp, h):
        return h + L.mlp(lp, h)

    def stage_fn(layers_local, h):
        def body(h, lp):
            return one_layer(lp, h), None
        h, _ = lax.scan(body, h, layers_local)
        return h

    # sequential reference
    ref = x
    for i in range(Lyr):
        lp = jax.tree_util.tree_map(lambda a: a[i], params)
        ref = one_layer(lp, ref)

    with mesh:
        out = pipeline_apply(stage_fn, params, x, mesh=mesh,
                             n_microbatches=4)
    err = float(jnp.abs(out - ref).max())
    rel = err / float(jnp.abs(ref).max())
    print("ERR", rel)
    assert rel < 1e-5, (err, rel)
""")


def test_pipeline_2_stages():
    out = _run(2, CODE.format(n=2))
    assert "ERR" in out


def test_pipeline_4_stages():
    out = _run(4, CODE.format(n=4))
    assert "ERR" in out
