# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
# and benches must see 1 device (the 512-device mesh exists only inside
# launch/dryrun.py and the subprocess-based elastic/sharding tests).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
