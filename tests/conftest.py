# NOTE: deliberately NO XLA_FLAGS device-count override here — smoke tests
# and benches must see 1 device (the 512-device mesh exists only inside
# launch/dryrun.py and the subprocess-based elastic/sharding tests).
import os
import random
import sys
import types

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# ---------------------------------------------------------------------------
# hypothesis guard: the property tests require hypothesis (see
# requirements-test.txt).  When it is missing we install a tiny
# deterministic stand-in — @given draws a fixed number of pseudo-random
# examples — so the suite still runs (with reduced case diversity) instead
# of dying at collection.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _make_stub():
        mod = types.ModuleType("hypothesis")
        st = types.ModuleType("hypothesis.strategies")
        mod.__version__ = "0.0-stub"

        class _Strategy:
            def __init__(self, gen):
                self.gen = gen

        def integers(min_value=0, max_value=100):
            return _Strategy(lambda rng: rng.randint(int(min_value),
                                                     int(max_value)))

        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(float(min_value),
                                                     float(max_value)))

        def lists(elements, min_size=0, max_size=10, **_kw):
            def gen(rng):
                n = rng.randint(min_size, max_size)
                return [elements.gen(rng) for _ in range(n)]
            return _Strategy(gen)

        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(0, 1)))

        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq))

        def given(**strategies):
            def deco(fn):
                n_examples = getattr(fn, "_stub_max_examples", 10)

                # NOTE: no functools.wraps — pytest must see a zero-arg
                # signature, not the original parametrized one
                def run():
                    rng = random.Random(0)
                    for _ in range(n_examples):
                        drawn = {k: s.gen(rng)
                                 for k, s in strategies.items()}
                        fn(**drawn)
                run.__name__ = fn.__name__
                run.__doc__ = fn.__doc__
                run.__module__ = fn.__module__
                return run
            return deco

        def settings(max_examples=10, **_kw):
            def deco(fn):
                fn._stub_max_examples = max_examples
                return fn
            return deco

        st.integers = integers
        st.floats = floats
        st.lists = lists
        st.booleans = booleans
        st.sampled_from = sampled_from
        mod.strategies = st
        mod.given = given
        mod.settings = settings
        mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
        sys.modules["hypothesis"] = mod
        sys.modules["hypothesis.strategies"] = st

    _make_stub()
