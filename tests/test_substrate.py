"""Checkpointing, data pipeline, optimizer, compression, serving, trainer."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step, load_checkpoint,
                              save_checkpoint)
from repro.core.tiers import Tier
from repro.data import SyntheticLMDataset, make_train_iterator
from repro.models import LMConfig
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, \
    cosine_schedule, make_optimizer
from repro.optim.compression import dequantize_int8, quantize_int8
from repro.serving import Request, ServingEngine, TieredScheduler
from repro.train import make_train_state, make_train_step
from repro.train.trainer import Trainer

CFG = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
               d_head=16, d_ff=128, vocab_size=128, tie_embeddings=True)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12).reshape(3, 4).astype(jnp.float32),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, tree, extra={"note": "x"})
        assert latest_step(d) == 7
        out, extra = load_checkpoint(d, tree)
        assert extra["note"] == "x"
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_async_checkpointer_gc():
    tree = {"w": jnp.ones((4, 4))}
    with tempfile.TemporaryDirectory() as d:
        ck = AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, tree)
        ck.wait()
        assert latest_step(d) == 4
        out, _ = load_checkpoint(d, tree)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.ones((4, 4)))


def test_trainer_resume_deterministic():
    """Preempt/restore (UFA BBM) must be bit-deterministic: train 10 straight
    vs train 5 + checkpoint + resume 5 must agree."""
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4, seed=2)
    step_fn, opt = make_train_step(CFG, n_loss_chunks=2)

    def losses_straight():
        st = make_train_state(CFG, jax.random.PRNGKey(0), opt)
        jstep = jax.jit(step_fn)
        out = []
        it = make_train_iterator(ds)
        for _ in range(10):
            st, m = jstep(st, next(it))
            out.append(float(m["loss"]))
        return out

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, step_fn, d, checkpoint_every=100)
        st = make_train_state(CFG, jax.random.PRNGKey(0), opt)
        st, rep1 = tr.run(st, make_train_iterator(ds), 5)
        st2 = make_train_state(CFG, jax.random.PRNGKey(9), opt)  # junk
        st2, start = tr.maybe_resume(st2)
        assert start == 5
        st2, rep2 = tr.run(st2, make_train_iterator(ds, start_step=start),
                           5, start_step=start)
        resumed = rep1.losses + rep2.losses
    straight = losses_straight()
    np.testing.assert_allclose(resumed, straight, rtol=1e-5)


def test_trainer_preempt_hook():
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4, seed=2)
    step_fn, opt = make_train_step(CFG, n_loss_chunks=2)
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(CFG, step_fn, d)
        st = make_train_state(CFG, jax.random.PRNGKey(0), opt)
        tr.request_preempt()
        st, rep = tr.run(st, make_train_iterator(ds), 10)
        assert rep.preempted and rep.steps_done == 0
        assert latest_step(d) is not None      # final checkpoint written


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_dataset_deterministic_and_learnable():
    ds = SyntheticLMDataset(vocab_size=64, seq_len=32, global_batch=4, seed=5)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert not np.array_equal(ds.batch(3)["inputs"], ds.batch(4)["inputs"])
    assert b1["inputs"].shape == (4, 32)
    # bigram structure: entropy of next-token given cluster < uniform
    assert b1["labels"].max() < 64


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    w = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(w)
    for _ in range(200):
        g = {"w": 2 * w["w"]}
        w, state, m = adamw_update(g, state, w, lr=0.05, weight_decay=0.0)
    assert float(jnp.abs(w["w"]).max()) < 0.3


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in
                         jax.tree_util.tree_leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-4)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)


@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 1000))
@settings(deadline=None, max_examples=20)
def test_int8_quantization_bounded_error(scale, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (256,)) * scale
    q, s = quantize_int8(x, jax.random.PRNGKey(seed + 1))
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 1.01   # within one quantization step


def test_int8_quantization_unbiased():
    x = jnp.full((20000,), 0.3)
    q, s = quantize_int8(x, jax.random.PRNGKey(0))
    mean = float(dequantize_int8(q, s).mean())
    assert abs(mean - 0.3) < 2e-3          # stochastic rounding unbiased


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def _params():
    from repro.models import init_params
    return init_params(CFG, jax.random.PRNGKey(0))


def test_engine_tier_blocking_and_preemption():
    eng = ServingEngine(CFG, _params(), max_batch=4, max_seq=48)
    rng = np.random.default_rng(0)
    mk = lambda i, t: Request(i, tier=t, prompt=list(rng.integers(0, 128, 8)),
                              max_new_tokens=3)
    eng.block_tiers({Tier.T5})
    admitted = eng.admit([mk(0, Tier.T1), mk(1, Tier.T5)])
    assert [r.tier for r in admitted] == [Tier.T1]
    assert eng.counters["rejected"][Tier.T5] == 1
    while eng.decode_round():
        pass
    assert eng.counters["served"][Tier.T1] == 1
    # preemption drops the wave and counts it
    eng.admit([mk(2, Tier.T3)])
    dropped = eng.preempt()
    assert dropped and dropped[0].state == "preempted"
    assert eng.availability(Tier.T1) == 1.0
    assert eng.availability(Tier.T5) == 0.0


def test_scheduler_failover_differentiated_availability():
    eng = ServingEngine(CFG, _params(), max_batch=4, max_seq=64)
    sched = TieredScheduler({"e": eng})
    rng = np.random.default_rng(1)
    for i in range(12):
        sched.submit(Request(i, tier=Tier(i % 6),
                             prompt=list(rng.integers(0, 128, 8)),
                             max_new_tokens=2))
    sched.enter_failover()
    for _ in range(40):
        sched.tick()
    # critical tiers keep serving; preemptible tiers fail fast
    assert eng.counters["served"][Tier.T0] + eng.counters["served"][Tier.T1] > 0
    assert eng.counters["served"][Tier.T4] == 0
    assert eng.counters["served"][Tier.T5] == 0
    sched.exit_failover()
    for i in range(12, 18):
        sched.submit(Request(i, tier=Tier.T5,
                             prompt=list(rng.integers(0, 128, 8)),
                             max_new_tokens=2))
    for _ in range(40):
        sched.tick()
    assert eng.counters["served"][Tier.T5] > 0   # restored after failback
