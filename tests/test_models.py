"""Model-family behaviour: forward/decode agreement, masking, MoE math."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (LMConfig, decode_step, forward, init_decode_state,
                          init_params, logits_fn)
from repro.models.layers import (moe_apply_local, moe_routing, ssd_chunked,
                                 _expert_positions, _expert_positions_big)

CFGS = {
    "dense": LMConfig(name="d", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab_size=97, qk_norm=True),
    "windowed": LMConfig(name="w", n_layers=6, d_model=64, n_heads=4,
                         n_kv_heads=2, d_head=16, d_ff=128, vocab_size=97,
                         window_pattern=(4, 4, 4, 4, 4, 0), rope_theta_local=1e3),
    "ssm": LMConfig(name="s", n_layers=2, d_model=64, n_heads=0, n_kv_heads=1,
                    d_head=1, d_ff=0, vocab_size=97, block="ssm", ssm_state=16,
                    ssm_head_dim=16, ssm_chunk=4),
    "hybrid": LMConfig(name="h", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
                       d_head=16, d_ff=128, vocab_size=97, block="hybrid",
                       ssm_state=8, ssm_head_dim=16, ssm_chunk=4,
                       window_pattern=(4, 4, 0)),
    "moe": LMConfig(name="m", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                    d_head=16, d_ff=96, vocab_size=97, n_experts=8, moe_top_k=2),
}


@pytest.mark.parametrize("fam", list(CFGS))
def test_forward_shapes_no_nan(fam):
    cfg = CFGS[fam]
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    h, aux = forward(p, cfg, toks)
    logits = logits_fn(p, cfg, h)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("fam", ["dense", "windowed", "ssm", "hybrid"])
def test_decode_matches_forward(fam):
    cfg = CFGS[fam]
    S = 12
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, S), 0, cfg.vocab_size)
    h, _ = forward(p, cfg, toks)
    lg_full = logits_fn(p, cfg, h)
    st = init_decode_state(cfg, 2, S + 4, jnp.float32)
    step = jax.jit(lambda st, t: decode_step(p, cfg, st, t))
    outs = []
    for t in range(S):
        lg, st = step(st, toks[:, t])
        outs.append(lg)
    err = float(jnp.abs(lg_full - jnp.stack(outs, 1)).max())
    assert err < 2e-3, err


def test_blocked_local_attention_exact():
    cfg = CFGS["windowed"]
    cfgb = dataclasses.replace(cfg, block_local_attn=True)
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, 97)
    h1, _ = forward(p, cfg, toks)
    h2, _ = forward(p, cfgb, toks)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-5)


def test_padded_heads_are_exact():
    """TP head padding (q AND kv padded proportionally, zero weights for the
    padded heads, zero wo rows) must not change outputs."""
    cfg = dataclasses.replace(CFGS["dense"], qk_norm=False)
    p = init_params(cfg, jax.random.PRNGKey(0))
    cfg_pad = dataclasses.replace(cfg, n_heads=8, n_kv_heads=4)
    p_pad = init_params(cfg_pad, jax.random.PRNGKey(1))
    attn, attn_p = p["layers"]["attn"], p_pad["layers"]["attn"]
    hd = cfg.d_head
    for name, real in (("wq", cfg.n_heads), ("wk", cfg.n_kv_heads),
                       ("wv", cfg.n_kv_heads)):
        w = np.zeros(attn_p[name].shape, np.float32)
        w[:, :, :real * hd] = np.asarray(attn[name])
        attn_p[name] = jnp.asarray(w)
    wo = np.zeros(attn_p["wo"].shape, np.float32)
    wo[:, :cfg.n_heads * hd, :] = np.asarray(attn["wo"])
    attn_p["wo"] = jnp.asarray(wo)
    p_pad["embed"] = p["embed"]
    p_pad["lm_head"] = p["lm_head"]
    p_pad["final_norm"] = p["final_norm"]
    for k in ("ln1", "ln2", "mlp"):
        p_pad["layers"][k] = p["layers"][k]
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, 97)
    h1, _ = forward(p, cfg, toks)
    h2, _ = forward(p_pad, cfg_pad, toks)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-5)


def test_ssd_chunked_matches_naive():
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    B, S, H, P, G, N, chunk = 2, 32, 4, 8, 2, 16, 8
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (B, S, G, N))
    c = jax.random.normal(ks[4], (B, S, G, N))
    y, fs = ssd_chunked(x, dt, a, b, c, chunk)

    bh = np.repeat(np.asarray(b), H // G, axis=2)
    ch = np.repeat(np.asarray(c), H // G, axis=2)
    st = np.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        dec = np.exp(np.asarray(dt)[:, t] * np.asarray(a)[None, :])
        st = st * dec[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", np.asarray(dt)[:, t], np.asarray(x)[:, t],
            bh[:, t])
        ys.append(np.einsum("bhpn,bhn->bhp", st, ch[:, t]))
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), st, rtol=1e-4, atol=1e-4)


def test_moe_positions_variants_agree():
    rng = np.random.default_rng(0)
    top_e = jnp.asarray(rng.integers(0, 7, size=(50, 3)))
    a = _expert_positions(top_e, 7)
    b = _expert_positions_big(top_e, 7)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_moe_no_drop_equals_dense_mixture():
    """With capacity >= T*k the dropped-MoE must equal the exact mixture."""
    D, E, F, T, K = 16, 4, 24, 12, 2
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    params = {
        "router": jax.random.normal(ks[0], (D, E)),
        "w_gate": jax.random.normal(ks[1], (E, D, F)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, D, F)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, F, D)) * 0.1,
    }
    x = jax.random.normal(ks[4], (T, D))
    y, _ = moe_apply_local(params, x, top_k=K, capacity=T * K, n_experts=E)
    w, e, _ = moe_routing(params["router"], x, K)
    y_ref = np.zeros((T, D), np.float32)
    for t in range(T):
        for s in range(K):
            ei = int(e[t, s])
            g = np.asarray(x[t] @ params["w_gate"][ei])
            u = np.asarray(x[t] @ params["w_up"][ei])
            h = (g / (1 + np.exp(-g))) * u
            y_ref[t] += float(w[t, s]) * (h @ np.asarray(params["w_down"][ei]))
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)


def test_expert_sharded_moe_sums_to_full():
    """Partial per-shard MoE outputs must sum to the unsharded result."""
    D, E, F, T, K = 16, 6, 24, 10, 2
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 5)
    params = {
        "router": jax.random.normal(ks[0], (D, E)),
        "w_gate": jax.random.normal(ks[1], (E, D, F)) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, D, F)) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, F, D)) * 0.1,
    }
    x = jax.random.normal(ks[4], (T, D))
    full, _ = moe_apply_local(params, x, top_k=K, capacity=T * K, n_experts=E)
    acc = jnp.zeros_like(full)
    for start in (0, 3):
        shard = {k: (v[start:start + 3] if k != "router" else v)
                 for k, v in params.items()}
        part, _ = moe_apply_local(shard, x, top_k=K, capacity=T * K,
                                  n_experts=E, expert_start=start,
                                  n_local_experts=3)
        acc = acc + part
    np.testing.assert_allclose(np.asarray(acc), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_kv_quant_decode_close_to_fp():
    """int8 KV cache (per-token-head scales) must track fp decode closely."""
    cfg = CFGS["dense"]
    cfgq = dataclasses.replace(cfg, kv_quant=True)
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 10), 0, 97)
    h, _ = forward(p, cfg, toks)
    lg_full = logits_fn(p, cfg, h)
    st = init_decode_state(cfgq, 2, 16, jnp.float32)
    assert st.k_cache.dtype == jnp.int8
    step = jax.jit(lambda st, t: decode_step(p, cfgq, st, t))
    outs = []
    for t in range(10):
        lg, st = step(st, toks[:, t])
        outs.append(lg)
    err = float(jnp.abs(lg_full - jnp.stack(outs, 1)).max())
    rel = err / float(jnp.abs(lg_full).max())
    assert rel < 0.05, rel
